"""Layer-2 JAX model: ResNet18 forward pass built from the Layer-1 Pallas
kernels, mirroring the Rust graph builder (`rust/src/cnn/resnet.rs`)
node-for-node so weights can be fed from the coordinator in node order.

BN is folded into conv weights (the paper treats CONV_BN_RELU as one
layer); weights are function *parameters*, so the AOT artifact can be fed
any weights from the Rust side (the e2e example feeds the same synthetic
weights the Rust validator generates).
"""

from dataclasses import dataclass

import jax.numpy as jnp

from .kernels import pim_kernels as K
from .kernels import ref as R


@dataclass(frozen=True)
class WeightSpec:
    """One weight tensor of the network, in Rust node order."""

    name: str
    shape: tuple  # (cout, cin, k, k) for conv, (cout, cin) for fc


def weight_specs(res: int = 32):
    """Weight tensors of ResNet18 in the exact Rust node order."""
    assert res % 32 == 0
    specs = [WeightSpec("conv1", (64, 3, 7, 7))]
    cin = 64
    for sidx, cout, _stride in ((1, 64, 1), (2, 128, 2), (3, 256, 2), (4, 512, 2)):
        for b in range(2):
            pfx = f"s{sidx}b{b}"
            specs.append(WeightSpec(f"{pfx}.conv1", (cout, cin, 3, 3)))
            specs.append(WeightSpec(f"{pfx}.conv2", (cout, cout, 3, 3)))
            if b == 0 and (cin != cout or sidx > 1):
                specs.append(WeightSpec(f"{pfx}.down", (cout, cin, 1, 1)))
            cin = cout
    specs.append(WeightSpec("fc", (1000, 512)))
    return specs


def _ops(use_pallas: bool):
    return K if use_pallas else R


def resnet18(x, weights, use_pallas: bool = False):
    """Forward pass. ``x``: (3, res, res) CHW; ``weights``: list in
    ``weight_specs`` order. ``use_pallas`` switches conv/pool/add to the
    Layer-1 kernels (interpret-mode; slower to trace, same numerics)."""
    ops = _ops(use_pallas)
    it = iter(weights)

    x = ops.conv2d(x, next(it), stride=2, pad=3, relu=True)
    x = ops.maxpool(x, 3, 2, 1)

    cin = 64
    for sidx, cout, stride in ((1, 64, 1), (2, 128, 2), (3, 256, 2), (4, 512, 2)):
        for b in range(2):
            s = stride if b == 0 else 1
            c1 = ops.conv2d(x, next(it), stride=s, pad=1, relu=True)
            c2 = ops.conv2d(c1, next(it), stride=1, pad=1, relu=False)
            if b == 0 and (cin != cout or sidx > 1):
                skip = ops.conv2d(x, next(it), stride=s, pad=0, relu=False)
            else:
                skip = x
            x = ops.add_relu(c2, skip)
            cin = cout

    x = R.global_avg(x)
    out = R.fc(x, next(it))
    rest = list(it)
    assert not rest, f"{len(rest)} unused weights"
    return out


def resnet18_first8(x, weights, use_pallas: bool = False):
    """The ResNet18_First8Layers workload: stem + stage 1 (ends at the
    s1b1 ADD_RELU). ``weights``: first 5 tensors of ``weight_specs``."""
    ops = _ops(use_pallas)
    it = iter(weights)
    x = ops.conv2d(x, next(it), stride=2, pad=3, relu=True)
    x = ops.maxpool(x, 3, 2, 1)
    for _b in range(2):
        c1 = ops.conv2d(x, next(it), stride=1, pad=1, relu=True)
        c2 = ops.conv2d(c1, next(it), stride=1, pad=1, relu=False)
        x = ops.add_relu(c2, x)
    rest = list(it)
    assert not rest
    return x


def num_params(res: int = 32) -> int:
    return sum(int(jnp.prod(jnp.array(s.shape))) for s in weight_specs(res))
