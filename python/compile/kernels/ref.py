"""Pure-jnp correctness oracles for the Pallas kernels (Layer 1's judge).

Conventions match the Rust validator (`rust/src/validate/tensor.rs`):
CHW tensors, weights ``[cout][cin][k][k]``, zero padding, max-pool
ignoring out-of-bounds taps, average counting the full window.
"""

import jax.numpy as jnp
from jax import lax


def conv2d(x, w, stride=1, pad=0, relu=False):
    """VALID/padded conv over a CHW tensor. ``w``: (cout, cin, k, k)."""
    xb = x[None]  # NCHW
    out = lax.conv_general_dilated(
        xb,
        w,
        window_strides=(stride, stride),
        padding=[(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    if relu:
        out = jnp.maximum(out, 0.0)
    return out


def maxpool(x, k, stride, pad):
    """Max pool; padding taps never win (−inf identity)."""
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, k, k),
        window_strides=(1, stride, stride),
        padding=[(0, 0), (pad, pad), (pad, pad)],
    )


def avgpool(x, k, stride, pad):
    """Average pool counting the full k*k window (torch default)."""
    s = lax.reduce_window(
        x,
        0.0,
        lax.add,
        window_dimensions=(1, k, k),
        window_strides=(1, stride, stride),
        padding=[(0, 0), (pad, pad), (pad, pad)],
    )
    return s / float(k * k)


def global_avg(x):
    return jnp.mean(x, axis=(1, 2), keepdims=True)


def add_relu(a, b):
    return jnp.maximum(a + b, 0.0)


def fc(x, w):
    """``x``: (cin,1,1) CHW; ``w``: (cout, cin)."""
    return (w @ x.reshape(-1))[:, None, None]


def fused_two_conv_tile(x_halo, w1, w2, relu1=True, relu2=True):
    """The fused-kernel contract: two chained VALID 3x3 convs on a haloed
    tile (halo = 2 pixels/side) — what one PIMcore computes for its tile
    in Fig. 1(b)."""
    t = conv2d(x_halo, w1, stride=1, pad=0, relu=relu1)
    return conv2d(t, w2, stride=1, pad=0, relu=relu2)


__all__ = [
    "conv2d",
    "maxpool",
    "avgpool",
    "global_avg",
    "add_relu",
    "fc",
    "fused_two_conv_tile",
]
