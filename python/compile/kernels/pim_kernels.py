"""Layer-1 Pallas kernels: the PIMcore compute hot-spots.

Each ``pallas_call`` program instance models one PIMcore executing a
``PIMcore_CMP`` command on its spatial tile (DESIGN.md
§Hardware-Adaptation):

* the convolution is expressed as k² MXU ``dot_general`` contractions
  over ``cin`` (weight-slice × activation-patch), the TPU-native
  rethinking of the paper's 16-lane near-bank MAC array;
* the input BlockSpec carries the halo (HBM→VMEM is the analogue of the
  bank→LBUF ``PIM_BK2LBUF`` path);
* the weight operand uses a constant index_map — every grid step sees
  the same weights, mirroring the GBUF broadcast.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; numerics are validated against ``ref.py`` by pytest and
real-TPU characteristics are reported analytically (``aot.py --report``).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_kernel(x_ref, w_ref, o_ref, *, k: int, stride: int, relu: bool):
    """VALID conv on one tile: x (cin, ih, iw), w (cout, cin, k, k),
    o (cout, oh, ow). Accumulates k² cin-contractions on the MXU."""
    cout, oh, ow = o_ref.shape
    x = x_ref[...]
    w = w_ref[...]
    acc = jnp.zeros((cout, oh, ow), dtype=jnp.float32)
    for ky in range(k):
        for kx in range(k):
            # Strided patch covering every output pixel's (ky, kx) tap.
            patch = jax.lax.slice(
                x,
                (0, ky, kx),
                (x.shape[0], ky + (oh - 1) * stride + 1, kx + (ow - 1) * stride + 1),
                (1, stride, stride),
            )  # (cin, oh, ow)
            wsl = w[:, :, ky, kx]  # (cout, cin)
            acc = acc + jax.lax.dot_general(
                wsl,
                patch.reshape(patch.shape[0], -1),
                (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.float32,
            ).reshape(cout, oh, ow)
    if relu:
        acc = jnp.maximum(acc, 0.0)
    o_ref[...] = acc


def conv2d_tile(x_halo, w, stride=1, relu=False):
    """VALID conv of a haloed CHW tile through the Pallas kernel."""
    cin, ih, iw = x_halo.shape
    cout, cin2, k, _ = w.shape
    assert cin == cin2, f"cin mismatch {cin} vs {cin2}"
    oh = (ih - k) // stride + 1
    ow = (iw - k) // stride + 1
    kern = functools.partial(_conv_kernel, k=k, stride=stride, relu=relu)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((cout, oh, ow), jnp.float32),
        interpret=True,
    )(x_halo, w)


def conv2d(x, w, stride=1, pad=0, relu=False):
    """Padded conv: zero-pad on the host side (the trace generator charges
    the halo fetch), VALID Pallas kernel inside."""
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    return conv2d_tile(x, w, stride=stride, relu=relu)


def _pool_kernel(x_ref, o_ref, *, k: int, stride: int, is_max: bool):
    x = x_ref[...]
    c, oh, ow = o_ref.shape
    acc = None
    for ky in range(k):
        for kx in range(k):
            patch = jax.lax.slice(
                x,
                (0, ky, kx),
                (c, ky + (oh - 1) * stride + 1, kx + (ow - 1) * stride + 1),
                (1, stride, stride),
            )
            if acc is None:
                acc = patch
            elif is_max:
                acc = jnp.maximum(acc, patch)
            else:
                acc = acc + patch
    o_ref[...] = acc if is_max else acc / float(k * k)


def maxpool(x, k, stride, pad):
    """Max pool through the Pallas kernel (−inf padding, as in ref)."""
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)), constant_values=-jnp.inf)
    cin, ih, iw = x.shape
    oh = (ih - k) // stride + 1
    ow = (iw - k) // stride + 1
    kern = functools.partial(_pool_kernel, k=k, stride=stride, is_max=True)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((cin, oh, ow), jnp.float32),
        interpret=True,
    )(x)


def avgpool(x, k, stride, pad):
    if pad:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    cin, ih, iw = x.shape
    oh = (ih - k) // stride + 1
    ow = (iw - k) // stride + 1
    kern = functools.partial(_pool_kernel, k=k, stride=stride, is_max=False)
    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((cin, oh, ow), jnp.float32),
        interpret=True,
    )(x)


def _add_relu_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.maximum(a_ref[...] + b_ref[...], 0.0)


def add_relu(a, b):
    """Residual ADD_RELU (the paper's PIMcore/GBcore execution flag)."""
    return pl.pallas_call(
        _add_relu_kernel,
        out_shape=jax.ShapeDtypeStruct(a.shape, jnp.float32),
        interpret=True,
    )(a, b)


def fused_two_conv_tile(x_halo, w1, w2, relu1=True, relu2=True):
    """Two chained VALID 3×3 convs on one haloed tile — the two-layer
    fused kernel of Fig. 1(b), one PIMcore's `PIMcore_CMP` work. The
    intermediate tile never leaves the core (VMEM ↔ LBUF analogy)."""
    t = conv2d_tile(x_halo, w1, stride=1, relu=relu1)
    return conv2d_tile(t, w2, stride=1, relu=relu2)
