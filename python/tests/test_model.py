"""Layer-2 model checks: shapes, parameter inventory, first8/full
consistency, and pallas-vs-ref agreement on the stem."""

import numpy as np
import pytest

import jax.numpy as jnp

from compile import model
from compile.kernels import ref as R


def _weights(res=32, seed=0, scale=0.1):
    rng = np.random.default_rng(seed)
    return [
        jnp.asarray(rng.standard_normal(s.shape), jnp.float32) * scale
        for s in model.weight_specs(res)
    ]


def test_weight_inventory_matches_resnet18():
    specs = model.weight_specs()
    # 1 stem + 4 convs/stage * 4 stages + 3 downsamples + fc = 21 tensors.
    assert len(specs) == 21
    names = [s.name for s in specs]
    assert names[0] == "conv1" and names[-1] == "fc"
    assert names.count("s2b0.down") == 1 and "s1b0.down" not in names
    # Conv+FC parameter count: torchvision's resnet18 has 11.69M params
    # including BN scales and the FC bias; with BN folded and no biases
    # the conv+fc tensors hold 11.68M.
    total = model.num_params()
    assert total == 11_678_912, total


def test_forward_shapes_at_32px():
    w = _weights()
    x = jnp.zeros((3, 32, 32), jnp.float32)
    out = model.resnet18(x, w)
    assert out.shape == (1000, 1, 1)
    first8 = model.resnet18_first8(x, w[:5])
    assert first8.shape == (64, 8, 8)


def test_first8_is_a_prefix_of_full():
    # Running the full model must produce the same stage-1 output as the
    # standalone first8 entry point (guards the mirrored builders).
    w = _weights(seed=3)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((3, 32, 32)), jnp.float32)

    first8 = model.resnet18_first8(x, w[:5])
    # Recompute the prefix manually with ref ops.
    t = R.conv2d(x, w[0], stride=2, pad=3, relu=True)
    t = R.maxpool(t, 3, 2, 1)
    for i in (1, 3):
        c1 = R.conv2d(t, w[i], stride=1, pad=1, relu=True)
        c2 = R.conv2d(c1, w[i + 1], stride=1, pad=1, relu=False)
        t = R.add_relu(c2, t)
    np.testing.assert_allclose(np.asarray(first8), np.asarray(t), rtol=1e-5, atol=1e-5)


@pytest.mark.slow
def test_pallas_model_matches_ref_model_first8():
    w = _weights(seed=9)
    rng = np.random.default_rng(10)
    x = jnp.asarray(rng.standard_normal((3, 32, 32)), jnp.float32)
    ref_out = model.resnet18_first8(x, w[:5], use_pallas=False)
    pal_out = model.resnet18_first8(x, w[:5], use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(pal_out), np.asarray(ref_out), rtol=1e-4, atol=1e-4
    )


def test_relu_nonnegativity_and_determinism():
    w = _weights(seed=1)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((3, 32, 32)), jnp.float32)
    a = model.resnet18_first8(x, w[:5])
    b = model.resnet18_first8(x, w[:5])
    assert float(jnp.min(a)) >= 0.0  # ends at an ADD_RELU
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
