"""AOT pipeline checks: every artifact lowers to loadable HLO text."""

import os
import subprocess
import sys

import pytest

from compile import aot


def test_report_mentions_vmem_and_mxu():
    r = aot.report()
    assert "VMEM" in r and "MXU" in r


@pytest.mark.parametrize("name,fn,specs", aot.artifact_entries(),
                         ids=[e[0] for e in aot.artifact_entries()])
def test_each_artifact_lowers_to_hlo_text(name, fn, specs, tmp_path):
    import jax

    lowered = jax.jit(fn).lower(*specs)
    text = aot.to_hlo_text(lowered)
    assert "ENTRY" in text, f"{name}: no ENTRY computation"
    assert len(text) > 200
    # The text must be pure HLO (no stablehlo/mhlo leftovers).
    assert "stablehlo." not in text


def test_cli_writes_files(tmp_path):
    out = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--only", "tile_conv_bn_relu"],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        capture_output=True,
        text=True,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr
    path = tmp_path / "tile_conv_bn_relu.hlo.txt"
    assert path.exists() and path.stat().st_size > 0
