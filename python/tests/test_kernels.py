"""Layer-1 correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes, strides and paddings; assert_allclose with
tight tolerances (same f32 compute, different op decomposition).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import pim_kernels as K
from compile.kernels import ref as R

RNG = np.random.default_rng(0)


def _rand(*shape):
    return jnp.asarray(RNG.standard_normal(shape, dtype=np.float32))


def _close(a, b, tol=1e-5):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=tol, atol=tol)


@settings(max_examples=25, deadline=None)
@given(
    cin=st.integers(1, 8),
    cout=st.integers(1, 8),
    k=st.sampled_from([1, 3, 5, 7]),
    stride=st.integers(1, 2),
    pad=st.integers(0, 3),
    hw=st.integers(6, 14),
    relu=st.booleans(),
)
def test_conv2d_matches_ref(cin, cout, k, stride, pad, hw, relu):
    if hw + 2 * pad < k:
        return
    x = _rand(cin, hw, hw)
    w = _rand(cout, cin, k, k) * 0.2
    got = K.conv2d(x, w, stride=stride, pad=pad, relu=relu)
    want = R.conv2d(x, w, stride=stride, pad=pad, relu=relu)
    assert got.shape == want.shape
    _close(got, want)


@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(1, 8),
    k=st.sampled_from([2, 3]),
    stride=st.integers(1, 2),
    pad=st.integers(0, 1),
    hw=st.integers(5, 13),
)
def test_maxpool_matches_ref(c, k, stride, pad, hw):
    x = _rand(c, hw, hw)
    got = K.maxpool(x, k, stride, pad)
    want = R.maxpool(x, k, stride, pad)
    assert got.shape == want.shape
    _close(got, want)


@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(1, 6),
    k=st.sampled_from([2, 3]),
    stride=st.integers(1, 2),
    hw=st.integers(5, 12),
)
def test_avgpool_matches_ref(c, k, stride, hw):
    x = _rand(c, hw, hw)
    got = K.avgpool(x, k, stride, 0)
    want = R.avgpool(x, k, stride, 0)
    _close(got, want)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(1, 8), hw=st.integers(1, 12))
def test_add_relu_matches_ref(c, hw):
    a, b = _rand(c, hw, hw), _rand(c, hw, hw)
    _close(K.add_relu(a, b), R.add_relu(a, b))


def test_conv_known_answer():
    # 3x3 all-ones kernel on an arange image: the window sum.
    x = jnp.arange(9.0, dtype=jnp.float32).reshape(1, 3, 3)
    w = jnp.ones((1, 1, 3, 3), jnp.float32)
    out = K.conv2d(x, w)
    assert out.shape == (1, 1, 1)
    assert float(out[0, 0, 0]) == 36.0


def test_conv_relu_clamps_negatives():
    x = jnp.ones((1, 4, 4), jnp.float32)
    w = -jnp.ones((1, 1, 3, 3), jnp.float32)
    out = K.conv2d(x, w, relu=True)
    assert float(jnp.max(out)) == 0.0


def test_strided_conv_shape():
    x = _rand(4, 11, 11)
    w = _rand(6, 4, 3, 3)
    out = K.conv2d(x, w, stride=2, pad=1)
    assert out.shape == (6, 6, 6)


def test_maxpool_padding_never_wins():
    # All-negative input: -inf pad must not leak into the output.
    x = -jnp.ones((1, 4, 4), jnp.float32) * 5.0
    out = K.maxpool(x, 3, 2, 1)
    assert float(jnp.max(out)) == -5.0
    assert np.isfinite(np.asarray(out)).all()


@settings(max_examples=15, deadline=None)
@given(c=st.integers(1, 6), tile=st.sampled_from([4, 8]), seed=st.integers(0, 10**6))
def test_fused_two_conv_tile_matches_ref(c, tile, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((c, tile + 4, tile + 4)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((c, c, 3, 3)), jnp.float32) * 0.2
    w2 = jnp.asarray(rng.standard_normal((c, c, 3, 3)), jnp.float32) * 0.2
    got = K.fused_two_conv_tile(x, w1, w2)
    want = R.fused_two_conv_tile(x, w1, w2)
    assert got.shape == (c, tile, tile)
    _close(got, want)
