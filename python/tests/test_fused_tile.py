"""The halo contract (Fig. 1(b)): a fused two-conv kernel computed on a
haloed tile must equal the corresponding slice of the full two-layer
(pad=1) network — the same property the Rust validator proves for whole
plans, here proven for the Layer-1 kernel that the AOT artifact ships.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import pim_kernels as K
from compile.kernels import ref as R


def _full_two_conv(x, w1, w2):
    t = R.conv2d(x, w1, stride=1, pad=1, relu=True)
    return R.conv2d(t, w2, stride=1, pad=1, relu=False)


@settings(max_examples=20, deadline=None)
@given(
    c=st.integers(1, 6),
    a=st.integers(1, 6),
    tile=st.sampled_from([4, 6, 8]),
    seed=st.integers(0, 10**6),
)
def test_interior_tile_equals_full_slice(c, a, tile, seed):
    hw = 16
    b = a + tile
    if b > hw - 1:  # keep the halo inside the padded map
        return
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((c, hw, hw)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((c, c, 3, 3)), jnp.float32) * 0.2
    w2 = jnp.asarray(rng.standard_normal((c, c, 3, 3)), jnp.float32) * 0.2

    full = _full_two_conv(x, w1, w2)

    # Haloed slice in padded coordinates: out tile [a,b) needs
    # xpad[a-1 : b+3] (halo 2 per side through two 3x3 convs).
    xpad = jnp.pad(x, ((0, 0), (1, 1), (1, 1)))
    halo = xpad[:, a - 1 : b + 3, a - 1 : b + 3]
    tile_out = K.fused_two_conv_tile(halo, w1, w2, relu1=True, relu2=False)

    want = full[:, a:b, a:b]
    np.testing.assert_allclose(
        np.asarray(tile_out), np.asarray(want), rtol=1e-5, atol=1e-5
    )


def test_interior_tiles_reassemble():
    # 2x2 grid of interior tiles of a larger map. (Border tiles need the
    # *intermediate* feature map's zero padding, which the VALID-chain
    # kernel cannot express — the Rust validator handles borders with
    # clamped demand regions instead; see rust/src/validate. The shipped
    # AOT artifact is the interior-tile contract.)
    rng = np.random.default_rng(7)
    c, hw, t = 4, 20, 8
    x = jnp.asarray(rng.standard_normal((c, hw, hw)), jnp.float32)
    w1 = jnp.asarray(rng.standard_normal((c, c, 3, 3)), jnp.float32) * 0.2
    w2 = jnp.asarray(rng.standard_normal((c, c, 3, 3)), jnp.float32) * 0.2
    full = _full_two_conv(x, w1, w2)

    # Output tile [a, a+t) needs xpad1[a-1 : a+t+3], xpad1 = pad(x, 1).
    xpad1 = jnp.pad(x, ((0, 0), (1, 1), (1, 1)))
    out = np.zeros((c, 2 * t, 2 * t), np.float32)
    for ty in range(2):
        for tx in range(2):
            a, bx = 2 + ty * t, 2 + tx * t
            halo = xpad1[:, a - 1 : a + t + 3, bx - 1 : bx + t + 3]
            tile = K.fused_two_conv_tile(halo, w1, w2, relu1=True, relu2=False)
            out[:, ty * t : (ty + 1) * t, tx * t : (tx + 1) * t] = np.asarray(tile)
    np.testing.assert_allclose(
        out, np.asarray(full[:, 2 : 2 + 2 * t, 2 : 2 + 2 * t]), rtol=1e-5, atol=1e-5
    )
